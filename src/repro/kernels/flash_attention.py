"""Pallas TPU kernel: FlashAttention (streaming-softmax attention).

Used by every attention-bearing assigned architecture (GQA / MLA-decoded /
SWA / cross-attention all reduce to this primitive after head expansion).
Standard online-softmax recurrence with the KV axis innermost in the grid
so the running (m, l, acc) state lives in VMEM scratch across KV blocks:

    grid = (B*H, Sq/bq, Skv/bk)           # kv innermost
    q block (1, bq, D), k/v blocks (1, bk, D), out (1, bq, D)
    scratch: m [bq,1], l [bq,1], acc [bq, D]   (float32)

Causal and sliding-window (SWA) masking are static specializations; fully
masked KV blocks are skipped with ``pl.when`` (block-level causal skip) —
on hardware this halves causal-attention work, and the same predicate
implements the O(S·W) sliding-window cost for `h2o-danube-3-4b`.

VMEM at bq=bk=128, D=128: q/k/v/out 64 KB each + scratch ~130 KB ≈ 0.4 MB.
MXU dims (bq, bk, D) are all multiples of 128 for head_dim 128 archs; the
wrapper pads smaller head dims (80/120) up to 128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, kv_len: int,
                  q_offset: int, bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level skip: with causal/window masking some KV blocks are
    # entirely masked for this query block
    row_hi = q_offset + qi * bq + bq - 1          # last query position
    row_lo = q_offset + qi * bq                   # first query position
    col_lo = ki * bk
    col_hi = ki * bk + bk - 1
    run = jnp.asarray(True)
    if causal:
        run = run & (col_lo <= row_hi)
    if window > 0:
        run = run & (col_hi >= row_lo - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, D]
        k = k_ref[0].astype(jnp.float32)          # [bk, D]
        v = v_ref[0].astype(jnp.float32)          # [bk, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        rows = q_offset + qi * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < kv_len
        if causal:
            mask = mask & (cols <= rows)
        if window > 0:
            mask = mask & (cols >= rows - window + 1)
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[...]                       # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "window", "bq", "bk", "q_offset", "interpret"))
def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = False, scale: float | None = None, window: int = 0,
    bq: int = 128, bk: int = 128, q_offset: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Attention over [B, H, S, D] tensors.

    ``window > 0`` enables sliding-window masking (implies causal-style
    locality: position i attends to [i-window+1, i]); combine with
    ``causal=True`` for autoregressive SWA. ``q_offset`` positions the
    query block within the KV sequence (decode: q_offset = kv_len - Sq).
    """
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    pq = (-Sq) % bq
    pk = (-Skv) % bk
    pd = (-D) % 128 if D > 128 else (128 - D if D < 128 else 0)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, pd)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, pd)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, pd)))
    Sqp, Skvp, Dp = Sq + pq, Skv + pk, D + pd

    qf = qp.reshape(B * H, Sqp, Dp)
    kf = kp.reshape(B * H, Skvp, Dp)
    vf = vp.reshape(B * H, Skvp, Dp)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        kv_len=Skv, q_offset=q_offset, bq=bq, bk=bk)
    out = pl.pallas_call(
        kern,
        grid=(B * H, Sqp // bq, Skvp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, Dp), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, Dp), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, Dp), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dp), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dp), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sqp, Dp)[:, :, :Sq, :D]
