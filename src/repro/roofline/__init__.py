from .analysis import (analyze_hlo, roofline_terms, RooflineReport,
                       parse_collectives, V5E)
