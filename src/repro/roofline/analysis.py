"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Inputs: ``lowered.compile()`` products — ``compiled.as_text()`` (optimized
per-device HLO), ``cost_analysis()``, ``memory_analysis()``. Outputs: the
three roofline terms per the brief:

    compute term    = HLO_FLOPs        / (chips × peak_FLOP/s)
    memory term     = HLO_bytes        / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

Two XLA gotchas this module corrects:

1. ``HloCostAnalysis`` visits each computation **once** — a 60-layer
   ``lax.scan`` (= ``while`` loop) body is counted once, undercounting
   FLOPs by 60×. We parse the HLO, recover each while loop's trip count
   from its condition's comparison constant, and scale every instruction
   inside the body (nested whiles multiply).
2. collective bytes are not in ``cost_analysis`` at all — we sum operand
   sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
   collective-permute, with the same trip-count scaling.

All parsed sizes are **per-device** (SPMD prints the per-shard program),
so ``terms = per_device_quantity / per_chip_peak`` — algebraically equal
to the brief's ``global / (chips × peak)`` form.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

#: ops that don't move data at runtime
_META_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota"}

#: ops whose operand/result bytes count toward the HBM-traffic term.
#: The dry-run compiles on the CPU backend, whose HLO leaves elementwise
#: chains unfused; on the TPU target XLA fuses them into their producer,
#: so counting every unfused add/mul would overstate HBM traffic ~50×.
#: We count the ops that are real HBM round-trips on TPU: matmuls/convs,
#: fusions, data movement (slices/updates/gather/scatter/copy), reductions
#: and collectives.
_BYTES_OPS = {
    "dot", "convolution", "fusion", "custom-call",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "scatter-add", "reduce", "reduce-window", "sort", "copy",
    "copy-start", "concatenate", "pad",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
}


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float      # per chip
    hbm_bw: float          # per chip, bytes/s
    link_bw: float         # per chip, bytes/s
    hbm_bytes: float


V5E = Hardware("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
               hbm_bytes=16e9)

#: Nominal envelope for the CPU container the benchmarks run in — a
#: conventional reference point (≈ a few AVX cores + dual-channel DDR),
#: NOT a measured machine. Achieved-rate percentages against it are for
#: *relative* comparison across kernels/runs on the same host; absolute
#: %-of-peak is only meaningful on a real accelerator target.
CPU_HOST = Hardware("cpu-host-nominal", peak_flops=2.0e11, hbm_bw=5.0e10,
                    link_bw=1.0e9, hbm_bytes=8e9)


def default_hardware() -> Hardware:
    """The roofline envelope for the current jax backend."""
    import jax
    return V5E if jax.default_backend() == "tpu" else CPU_HOST


@dataclasses.dataclass
class RooflineReport:
    flops: float                     # per device, trip-count corrected
    bytes_accessed: float            # per device
    collective_bytes: float          # per device
    collective_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    n_collective_ops: int

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32"
                       r"|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(line: str) -> float:
    """Sum all shape literals in the result type (LHS of the op name)."""
    rhs = line.split(" = ", 1)
    if len(rhs) != 2:
        return 0.0
    # result type is everything up to the first op token after '= '
    m = re.match(r"\s*(\(.*?\)|\S+)\s", rhs[1])
    head = m.group(1) if m else rhs[1]
    return float(sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head)))


def _split_computations(text: str) -> Dict[str, List[str]]:
    """computation name → its instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-~]+)\s*(?:\([^)]*\))?.*\{",
                         line)
            if m and not line.startswith(" "):
                cur = m.group(1)
                comps[cur] = []
        else:
            if stripped == "}" or stripped.startswith("}"):
                cur = None
            elif stripped:
                comps[cur].append(stripped)
    return comps


def _while_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """computation name → execution-count multiplier from while loops."""
    mult: Dict[str, float] = {name: 1.0 for name in comps}
    # find while ops: condition=..., body=...
    edges: List[Tuple[str, str, str]] = []   # (parent, cond, body)
    for parent, lines in comps.items():
        for line in lines:
            if " while(" in line or re.search(r"\bwhile\(", line):
                mc = re.search(r"condition=%?([\w.\-~]+)", line)
                mb = re.search(r"body=%?([\w.\-~]+)", line)
                if mc and mb:
                    edges.append((parent, mc.group(1), mb.group(1)))

    def trip_count(cond_name: str) -> float:
        best = 1.0
        for line in comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, float(m.group(1)))
        return best

    # propagate: body multiplier = parent multiplier × trip count.
    # iterate to fixpoint (nesting depth ≤ 3 in practice)
    for _ in range(6):
        changed = False
        for parent, cond, body in edges:
            tc = trip_count(cond)
            new = mult.get(parent, 1.0) * tc
            for target in (body, cond):
                if target in mult and mult[target] < new:
                    mult[target] = new
                    changed = True
        if not changed:
            break
    return mult


def _group_size(line: str, default: int) -> int:
    """#participants of a collective from replica_groups annotation."""
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return default


def parse_collectives(text: str, n_devices: int = 1
                      ) -> Tuple[float, Dict[str, float], int]:
    """→ (total per-device collective bytes, per-op-kind breakdown, #ops).

    Byte convention (operand bytes, per brief): all-reduce / all-to-all /
    collective-permute move ≈ result bytes; all-gather's operand is
    result/G; reduce-scatter's operand is result×G.
    """
    comps = _split_computations(text)
    mult = _while_multipliers(comps)
    total = 0.0
    breakdown: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    count = 0
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0)
        for line in lines:
            for kind in COLLECTIVES:
                if re.search(rf"\b{kind}(?:-start|-done)?\(", line):
                    if f"{kind}-done" in line:
                        continue  # counted at -start
                    rb = _result_bytes(line)
                    g = _group_size(line, n_devices)
                    if kind == "all-gather":
                        b = rb / max(g, 1)
                    elif kind == "reduce-scatter":
                        b = rb * g
                    else:
                        b = rb
                    total += b * m
                    breakdown[kind] += b * m
                    count += 1
                    break
    return total, breakdown, count


_DOT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def parse_flops_and_bytes(text: str) -> Tuple[float, float]:
    """Per-device (FLOPs, HBM bytes) from optimized HLO, trip-corrected.

    FLOPs: dot/convolution ops (2·result·K). Bytes: operands + results of
    every executed non-meta top-level instruction (post-fusion HLO reads
    each operand once and writes each result once — the roofline
    convention).
    """
    comps = _split_computations(text)
    mult = _while_multipliers(comps)

    # name → shape-bytes and name → dims for operand lookup
    shapes: Dict[str, Tuple[str, str]] = {}
    for lines in comps.values():
        for line in lines:
            m = re.match(r"%?([\w.\-~]+)\s*=\s*", line)
            if not m:
                continue
            sm = _SHAPE_RE.search(line.split(" = ", 1)[1])
            if sm:
                shapes[m.group(1)] = (sm.group(1), sm.group(2))

    def dims_of(name: str) -> List[int]:
        if name not in shapes:
            return []
        d = shapes[name][1]
        return [int(x) for x in d.split(",")] if d else []

    flops = 0.0
    byts = 0.0
    # fusion computations are *not* executed standalone; their caller
    # (the fusion op) accounts for the IO. Mark them.
    fused = {name for name in comps if name.startswith("fused_computation")
             or ".fused" in name}
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0)
        in_fused = cname in fused
        for line in lines:
            lm = re.match(r"%?([\w.\-~]+)\s*=\s*", line)
            if not lm:
                continue
            opm = re.search(r"\)?\s([a-z][a-z0-9\-]*)\(", line)
            op = opm.group(1) if opm else ""
            # --- flops: count inside fusions too (they execute) ---------
            if op in ("dot", "convolution"):
                out_elems = 1
                for d in dims_of(lm.group(1)):
                    out_elems *= d
                k = 1
                operands = re.findall(r"\(%?([\w.\-~]+)[,)]", line)
                cd = _DOT_RE.search(line)
                if op == "dot" and cd and operands:
                    ldims = dims_of(operands[0])
                    if cd.group(1):
                        for i in cd.group(1).split(","):
                            if int(i) < len(ldims):
                                k *= ldims[int(i)]
                elif op == "convolution" and len(operands) > 1:
                    kd = dims_of(operands[1])
                    if kd:
                        k = max(1, int(
                            (1.0 * _prod(kd)) / max(kd[-1] if kd else 1, 1)))
                flops += 2.0 * out_elems * k * m
            # --- bytes: top-level executed instructions, fusion-aware ----
            if not in_fused and op in _BYTES_OPS:
                rb = _result_bytes(line)
                if op in ("fusion", "custom-call"):
                    # fusions in while bodies list the whole carried tuple
                    # as operands but only *read a slice*; approximate a
                    # fusion's HBM traffic as write + equal-sized read.
                    byts += 2.0 * rb * m
                else:
                    ob = 0.0
                    for operand in re.findall(
                            r"%([\w.\-~]+)", line.split(
                                "(", 1)[1] if "(" in line else ""):
                        if operand in shapes:
                            ob += _shape_bytes(*shapes[operand])
                    byts += (rb + ob) * m
    return flops, byts


def _prod(xs):
    n = 1
    for x in xs:
        n *= x
    return n


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def analyze_hlo(text: str, hw: Hardware = V5E,
                cost_analysis: Optional[Dict] = None,
                n_devices: int = 1) -> RooflineReport:
    flops, byts = parse_flops_and_bytes(text)
    coll, breakdown, nops = parse_collectives(text, n_devices)
    # fall back to XLA's flop count when ours comes out lower (ours skips
    # elementwise flops; XLA's skips while-loop trip counts — take the max.
    # bytes stay ours: XLA's count reflects the unfused CPU backend.)
    if cost_analysis:
        flops = max(flops, float(cost_analysis.get("flops", 0.0)))
    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    collective_s = coll / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return RooflineReport(
        flops=flops, bytes_accessed=byts, collective_bytes=coll,
        collective_breakdown=breakdown, compute_s=compute_s,
        memory_s=memory_s, collective_s=collective_s, dominant=dominant,
        n_collective_ops=nops)


def roofline_terms(report: RooflineReport) -> Dict[str, float]:
    return {"compute_s": report.compute_s, "memory_s": report.memory_s,
            "collective_s": report.collective_s,
            "dominant": report.dominant}


# ---------------------------------------------------------------------------
# analytic per-kernel traffic models (benchmarks)
# ---------------------------------------------------------------------------
# Counting convention: one HBM read per operand, one write per result,
# per *stage* — fused stages keep intermediates on-chip and therefore
# drop the inter-stage round-trips. f32 elements are 4 bytes; an edge
# row is 2×int32 = 8 bytes. These are deterministic models, not
# measurements: benchmarks use them for fused-vs-unfused traffic ratios
# (machine-independent) and to convert measured wall time into achieved
# GB/s / %-of-roofline rows.

def mp_layer_traffic(p: int, q: int, f: int, h: int, *, mode: str = "mean",
                     combine: str = "split",
                     fused: bool = False) -> Dict[str, float]:
    """FLOPs + HBM bytes of one packed message-passing layer.

    Unfused = the composed per-op pipeline (gather → mask → scatter
    [→ degree → mean] → combine → bias/act/node-mask), each stage
    round-tripping its intermediate through HBM. Fused = the megakernel:
    inputs read once, output written once, everything else in VMEM.
    """
    nw = 2 if combine == "split" else 1      # weight matmuls in combine
    flops = 2.0 * q * f                      # scatter-accumulate MACs
    flops += 2.0 * p * f * h * nw            # combine matmul(s)
    flops += p * h                           # bias + activation
    if mode == "mean":
        flops += p * f                       # degree divide
    weights = f * h * nw + h
    if fused:
        elems = (p * f                       # x, read once
                 + q                         # edge_mask
                 + 2 * p                     # node mask + self-scale
                 + weights
                 + p * h)                    # output, written once
        byts = 4.0 * elems + 8.0 * q         # + edges (2×int32)
    else:
        elems = (p * f + q * f               # gather: read x, write msgs
                 + 2.0 * q * f               # mask: rewrite msgs
                 + q * f + p * f             # scatter: read msgs, write agg
                 + 2.0 * p * f + p           # combine reads x + agg (+ss)
                 + weights + p * h           # weights, write y
                 + 2.0 * p * h + p)          # act+mask rewrite
        if mode == "mean":
            elems += (q + p                  # degree pass
                      + 2.0 * p * f + p)     # mean divide rewrite
        byts = 4.0 * elems + 8.0 * q
    return {"flops": flops, "bytes": byts}


def segment_aggregate_traffic(b: int, e: int, n: int, f: int, *,
                              mode: str = "mean") -> Dict[str, float]:
    """Two-pass sparse aggregation: gather writes ``[E, F]`` messages,
    scatter reads them back — per batch row, ×``b``."""
    flops = b * (2.0 * e * f + (n * f if mode == "mean" else 0.0))
    elems = b * (n * f + e * f               # gather: read h, write msgs
                 + e + e * f + n * f         # scatter: mask, msgs, out
                 + (e + n if mode == "mean" else 0))
    return {"flops": flops, "bytes": 4.0 * elems + 8.0 * b * e}


def segment_readout_traffic(p: int, f: int, g: int, *,
                            kind: str = "mean_max") -> Dict[str, float]:
    """Fused segment mean/max readout over the packed flat node axis."""
    out_f = 2 * f if kind == "mean_max" else f
    flops = 2.0 * p * f + g * f              # sum+max sweep, mean divide
    elems = p * f + 2.0 * p + g * out_f + g  # h, ids+mask, out, counts
    return {"flops": flops, "bytes": 4.0 * elems}


def edge_softmax_traffic(b: int, e: int, h: int, n: int) -> Dict[str, float]:
    """Two-pass online edge softmax: stats pass + normalize pass."""
    flops = b * 5.0 * e * h                  # exp, sub, mul, div, max
    elems = b * (2.0 * e * h                 # scores read twice (2 passes)
                 + 2.0 * e                   # dst + mask (per pass, int/f32)
                 + 2.0 * n * h               # write (max, denom)
                 + 2.0 * n * h               # read them back
                 + e * h)                    # output
    return {"flops": flops, "bytes": 4.0 * elems}


def dense_aggregate_traffic(b: int, n: int, f: int) -> Dict[str, float]:
    """Dense-adjacency aggregation — the O(N²) path the sparse kernels
    replace (kept for microbench comparison rows)."""
    flops = 2.0 * b * n * n * f
    elems = b * (n * n + 2.0 * n * f)
    return {"flops": flops, "bytes": 4.0 * elems}


def achieved_rates(flops: float, byts: float, wall_s: float,
                   hw: Optional[Hardware] = None) -> Dict[str, object]:
    """Measured wall time + modeled (FLOPs, bytes) → achieved-rate row.

    ``pct_of_roofline`` is the fraction of the wall time explained by
    the binding roofline term — 100 % means the kernel runs at the
    envelope's speed-of-light for its arithmetic intensity; low values
    mean overhead (dispatch, interpret mode) dominates. Against
    :data:`CPU_HOST` the absolute number is nominal (see its docstring);
    the fused-vs-unfused *ratio* is the machine-independent signal.
    """
    hw = hw or default_hardware()
    wall = max(float(wall_s), 1e-12)
    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    return {
        "hardware": hw.name,
        "flops": float(flops),
        "bytes": float(byts),
        "achieved_gflops": flops / wall / 1e9,
        "achieved_gb_s": byts / wall / 1e9,
        "pct_peak_flops": 100.0 * (flops / wall) / hw.peak_flops,
        "pct_peak_bw": 100.0 * (byts / wall) / hw.hbm_bw,
        "bound": "compute" if compute_s >= memory_s else "memory",
        "pct_of_roofline": 100.0 * max(compute_s, memory_s) / wall,
    }
