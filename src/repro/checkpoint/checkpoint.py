"""Sharded, atomic, async-capable checkpointing.

Design (scaled-down orbax): one directory per step,
``step_<N>/shard_<H>.npz`` per host plus a ``manifest.json`` written
LAST — a checkpoint is valid iff its manifest exists (atomic commit), so
a mid-write failure leaves only ignorable garbage. Restore can RESHARD:
arrays are saved unsharded per-host (host-local slices concatenated
logically by the manifest), so a checkpoint written on a 512-chip mesh
restores onto 256 chips (elastic downscale) or a laptop.

On this single-process container every array is fully addressable, so
"host shard" degenerates to one file — the layout and commit protocol are
what the tests exercise (including crash-mid-write and reshard-restore).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for kp, leaf in leaves:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        flat[key] = np.asarray(leaf)
    return flat


def _tree_structure_of(tree):
    return jax.tree_util.tree_structure(tree)


def save_checkpoint(path: str, step: int, state: Any,
                    host_id: int = 0, n_hosts: int = 1) -> str:
    """Write ``state`` (pytree) for ``step``; manifest commits atomically."""
    step_dir = os.path.join(path, f"step_{step:010d}")
    os.makedirs(step_dir, exist_ok=True)
    flat = _flatten(state)
    shard_file = os.path.join(step_dir, f"shard_{host_id:05d}.npz")
    tmp = shard_file + ".tmp"
    with open(tmp, "wb") as f:  # np.savez(path) appends ".npz" — use a fh
        np.savez(f, **{k.replace("/", "__"): v for k, v in flat.items()})
    os.replace(tmp, shard_file)

    if host_id == 0:
        manifest = {
            "step": step,
            "n_hosts": n_hosts,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "time": time.time(),
        }
        mtmp = os.path.join(step_dir, "manifest.json.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(step_dir, "manifest.json"))
    return step_dir


def latest_step(path: str) -> Optional[int]:
    """Highest step with a committed manifest (ignores torn writes)."""
    if not os.path.isdir(path):
        return None
    best = None
    for name in os.listdir(path):
        if not name.startswith("step_"):
            continue
        if not os.path.exists(os.path.join(path, name, "manifest.json")):
            continue  # uncommitted / torn
        try:
            s = int(name.split("_")[1])
        except ValueError:
            continue
        best = s if best is None or s > best else best
    return best


def restore_checkpoint(path: str, step: Optional[int], like: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (a pytree of NamedSharding) — this is the reshard path:
    the same bytes lay out onto whatever mesh the new job runs."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {path}")
    step_dir = os.path.join(path, f"step_{step:010d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data: Dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(step_dir)):
        if name.startswith("shard_") and name.endswith(".npz"):
            blob = np.load(os.path.join(step_dir, name))
            for k in blob.files:
                data[k.replace("__", "/")] = blob[k]
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    for kp, leaf in leaves:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        if key not in data:
            raise KeyError(f"checkpoint missing key {key}")
        arr = data[key]
        out_leaves.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, out_leaves)
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    return restored


class CheckpointManager:
    """Step-addressed manager: keep-last-k GC + async save thread."""

    def __init__(self, path: str, keep: int = 3, save_async: bool = True):
        self.path = path
        self.keep = keep
        self.save_async = save_async
        self._thread: Optional[threading.Thread] = None
        os.makedirs(path, exist_ok=True)

    def save(self, step: int, state: Any) -> None:
        # snapshot to host memory synchronously (cheap), write async
        flat_np = jax.tree_util.tree_map(np.asarray, state)
        if self.save_async:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, flat_np), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, flat_np)

    def _save_and_gc(self, step: int, state: Any) -> None:
        save_checkpoint(self.path, step, state)
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.path)
            if n.startswith("step_") and os.path.exists(
                os.path.join(self.path, n, "manifest.json")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:010d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> tuple[Any, Optional[int]]:
        step = latest_step(self.path)
        if step is None:
            return like, None
        self.wait()
        return restore_checkpoint(self.path, step, like, shardings), step
