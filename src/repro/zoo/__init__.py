from .families import FAMILIES, build_family, family_variants
