"""Model zoo — the 10 Table-2 families as parametric JAX callables.

The paper's dataset (10,508 graphs) spans Efficientnet / Mnasnet /
Mobilenet / Resnet / Vgg / Swin / ViT / Densenet / Visformer / Poolformer
at many depth/width/resolution/batch points. Each family here is a
generator: ``build(variant_cfg) -> (param_specs, forward, meta)`` where
``param_specs`` is a pytree of ``jax.ShapeDtypeStruct`` (no allocation —
tracing is abstract) and ``forward(params, x)`` is jax-traceable.

These models only ever run under ``jax.make_jaxpr`` for graph extraction;
they are *shape programs*. That is exactly what DIPPM needs: the operator
graph with shapes/attributes, not trained weights.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as S
from jax import lax

F32 = jnp.float32


# ---------------------------------------------------------------------------
# spec-building helpers
# ---------------------------------------------------------------------------

def _conv_spec(cin, cout, k=3):
    return {"w": S((k, k, cin, cout), F32)}


def _dw_spec(c, k=3):
    # depthwise: HWIO with I=1, feature_group_count=c
    return {"w": S((k, k, 1, c), F32)}


def _dense_spec(din, dout, bias=True):
    p = {"w": S((din, dout), F32)}
    if bias:
        p["b"] = S((dout,), F32)
    return p


def _ln_spec(d):
    return {"g": S((d,), F32), "b": S((d,), F32)}


def _bn_spec(c):
    return {"g": S((c,), F32), "b": S((c,), F32)}


# ---------------------------------------------------------------------------
# forward helpers (NHWC)
# ---------------------------------------------------------------------------

def conv(p, x, stride=1, groups=1, padding="SAME"):
    return lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def dwconv(p, x, stride=1, padding="SAME"):
    c = x.shape[-1]
    return lax.conv_general_dilated(
        x, p["w"], (stride, stride), padding,
        feature_group_count=c,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def bn(p, x):
    # inference-mode affine (folded statistics)
    return x * p["g"] + p["b"]


def ln(p, x, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * p["g"] + p["b"]


def dense(p, x):
    y = x @ p["w"]
    return y + p["b"] if "b" in p else y


def maxpool(x, k=2, s=2):
    return lax.reduce_window(x, -jnp.inf, lax.max,
                             (1, k, k, 1), (1, s, s, 1), "SAME")


def avgpool(x, k=2, s=2):
    summed = lax.reduce_window(x, 0.0, lax.add,
                               (1, k, k, 1), (1, s, s, 1), "SAME")
    return summed / float(k * k)


def gap(x):
    return jnp.mean(x, axis=(1, 2))


def mha(p, x, heads):
    B, N, D = x.shape
    q = dense(p["q"], x).reshape(B, N, heads, D // heads)
    k = dense(p["k"], x).reshape(B, N, heads, D // heads)
    v = dense(p["v"], x).reshape(B, N, heads, D // heads)
    att = jnp.einsum("bnhd,bmhd->bhnm", q, k) / jnp.sqrt(D / heads)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhnm,bmhd->bnhd", att, v).reshape(B, N, D)
    return dense(p["o"], o)


def _mha_spec(d):
    return {"q": _dense_spec(d, d), "k": _dense_spec(d, d),
            "v": _dense_spec(d, d), "o": _dense_spec(d, d)}


def tx_block(p, x, heads, mlp_ratio=4):
    x = x + mha(p["attn"], ln(p["ln1"], x), heads)
    h = dense(p["fc1"], ln(p["ln2"], x))
    h = jax.nn.gelu(h)
    x = x + dense(p["fc2"], h)
    return x


def _tx_spec(d, mlp_ratio=4):
    return {"ln1": _ln_spec(d), "attn": _mha_spec(d), "ln2": _ln_spec(d),
            "fc1": _dense_spec(d, d * mlp_ratio),
            "fc2": _dense_spec(d * mlp_ratio, d)}


# ===========================================================================
# families
# ===========================================================================

def build_vgg(cfg):
    convs_per_stage = cfg.get("convs", [2, 2, 3, 3, 3])  # vgg16
    wm = cfg.get("width", 1.0)
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)
    widths = [max(16, int(w * wm)) for w in (64, 128, 256, 512, 512)]

    specs: Dict[str, Any] = {}
    cin = 3
    for si, (n, cout) in enumerate(zip(convs_per_stage, widths)):
        for ci in range(n):
            specs[f"s{si}c{ci}"] = _conv_spec(cin, cout, 3)
            cin = cout
    feat = widths[-1] * (res // 2 ** len(widths)) ** 2
    specs["fc1"] = _dense_spec(feat, 4096)
    specs["fc2"] = _dense_spec(4096, 4096)
    specs["head"] = _dense_spec(4096, 1000)

    def fwd(p, x):
        for si, n in enumerate(convs_per_stage):
            for ci in range(n):
                x = jax.nn.relu(conv(p[f"s{si}c{ci}"], x))
            x = maxpool(x)
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(dense(p["fc1"], x))
        x = jax.nn.relu(dense(p["fc2"], x))
        return dense(p["head"], x)

    return specs, fwd, {"family": "vgg", "batch": batch, "res": res}


def build_resnet(cfg):
    depths = cfg.get("depths", [2, 2, 2, 2])
    wm = cfg.get("width", 1.0)
    bottleneck = cfg.get("bottleneck", False)
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)
    widths = [max(16, int(w * wm)) for w in (64, 128, 256, 512)]
    exp = 4 if bottleneck else 1

    specs: Dict[str, Any] = {"stem": _conv_spec(3, widths[0], 7),
                             "stem_bn": _bn_spec(widths[0])}
    cin = widths[0]
    for si, (n, w) in enumerate(zip(depths, widths)):
        for bi in range(n):
            blk = {}
            if bottleneck:
                blk["c1"] = _conv_spec(cin, w, 1)
                blk["c2"] = _conv_spec(w, w, 3)
                blk["c3"] = _conv_spec(w, w * exp, 1)
                blk["bn1"], blk["bn2"], blk["bn3"] = (_bn_spec(w), _bn_spec(w),
                                                      _bn_spec(w * exp))
            else:
                blk["c1"] = _conv_spec(cin, w, 3)
                blk["c2"] = _conv_spec(w, w, 3)
                blk["bn1"], blk["bn2"] = _bn_spec(w), _bn_spec(w)
            if cin != w * exp:
                blk["proj"] = _conv_spec(cin, w * exp, 1)
            specs[f"s{si}b{bi}"] = blk
            cin = w * exp
    specs["head"] = _dense_spec(cin, 1000)

    def fwd(p, x):
        x = jax.nn.relu(bn(p["stem_bn"], conv(p["stem"], x, stride=2)))
        x = maxpool(x, 3, 2)
        for si, n in enumerate(depths):
            for bi in range(n):
                blk = p[f"s{si}b{bi}"]
                stride = 2 if (bi == 0 and si > 0) else 1
                idn = x
                if bottleneck:
                    y = jax.nn.relu(bn(blk["bn1"], conv(blk["c1"], x, 1)))
                    y = jax.nn.relu(bn(blk["bn2"], conv(blk["c2"], y, stride)))
                    y = bn(blk["bn3"], conv(blk["c3"], y, 1))
                else:
                    y = jax.nn.relu(bn(blk["bn1"], conv(blk["c1"], x, stride)))
                    y = bn(blk["bn2"], conv(blk["c2"], y, 1))
                if "proj" in blk:
                    idn = conv(blk["proj"], x, stride)
                elif stride != 1:
                    idn = avgpool(x, stride, stride)
                x = jax.nn.relu(y + idn)
        return dense(p["head"], gap(x))

    return specs, fwd, {"family": "resnet", "batch": batch, "res": res}


def build_densenet(cfg):
    blocks = cfg.get("blocks", [6, 12, 24, 16])   # densenet121
    growth = cfg.get("growth", 32)
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)

    specs: Dict[str, Any] = {"stem": _conv_spec(3, 2 * growth, 7),
                             "stem_bn": _bn_spec(2 * growth)}
    c = 2 * growth
    for si, n in enumerate(blocks):
        for bi in range(n):
            specs[f"s{si}b{bi}"] = {
                "bn1": _bn_spec(c), "c1": _conv_spec(c, 4 * growth, 1),
                "bn2": _bn_spec(4 * growth),
                "c2": _conv_spec(4 * growth, growth, 3)}
            c += growth
        if si < len(blocks) - 1:
            specs[f"t{si}"] = {"bn": _bn_spec(c), "c": _conv_spec(c, c // 2, 1)}
            c = c // 2
    specs["final_bn"] = _bn_spec(c)
    specs["head"] = _dense_spec(c, 1000)

    def fwd(p, x):
        x = jax.nn.relu(bn(p["stem_bn"], conv(p["stem"], x, 2)))
        x = maxpool(x, 3, 2)
        for si, n in enumerate(blocks):
            for bi in range(n):
                blk = p[f"s{si}b{bi}"]
                y = conv(blk["c1"], jax.nn.relu(bn(blk["bn1"], x)), 1)
                y = conv(blk["c2"], jax.nn.relu(bn(blk["bn2"], y)), 1)
                x = jnp.concatenate([x, y], axis=-1)
            if si < len(blocks) - 1:
                t = p[f"t{si}"]
                x = conv(t["c"], jax.nn.relu(bn(t["bn"], x)), 1)
                x = avgpool(x)
        x = jax.nn.relu(bn(p["final_bn"], x))
        return dense(p["head"], gap(x))

    return specs, fwd, {"family": "densenet", "batch": batch, "res": res}


def _inv_residual_specs(cin, cout, expand, k):
    mid = cin * expand
    s = {"e": _conv_spec(cin, mid, 1), "ebn": _bn_spec(mid),
         "dw": _dw_spec(mid, k), "dwbn": _bn_spec(mid),
         "p": _conv_spec(mid, cout, 1), "pbn": _bn_spec(cout)}
    return s


def _inv_residual(p, x, stride, use_res):
    y = jax.nn.relu6(bn(p["ebn"], conv(p["e"], x, 1)))
    y = jax.nn.relu6(bn(p["dwbn"], dwconv(p["dw"], y, stride)))
    y = bn(p["pbn"], conv(p["p"], y, 1))
    return x + y if use_res else y


def build_mobilenet(cfg):
    # MobileNetV2-style inverted residuals
    wm = cfg.get("width", 1.0)
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)
    settings = cfg.get("settings", [
        # (expand, cout, n, stride)
        (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
        (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)])
    def ch(c): return max(8, int(c * wm))

    specs: Dict[str, Any] = {"stem": _conv_spec(3, ch(32), 3),
                             "stem_bn": _bn_spec(ch(32))}
    cin = ch(32)
    for si, (e, c, n, s0) in enumerate(settings):
        for bi in range(n):
            specs[f"s{si}b{bi}"] = _inv_residual_specs(cin, ch(c), e, 3)
            cin = ch(c)
    specs["last"] = _conv_spec(cin, ch(1280), 1)
    specs["last_bn"] = _bn_spec(ch(1280))
    specs["head"] = _dense_spec(ch(1280), 1000)

    def fwd(p, x):
        x = jax.nn.relu6(bn(p["stem_bn"], conv(p["stem"], x, 2)))
        cin_l = ch(32)
        for si, (e, c, n, s0) in enumerate(settings):
            for bi in range(n):
                stride = s0 if bi == 0 else 1
                use_res = stride == 1 and cin_l == ch(c)
                x = _inv_residual(p[f"s{si}b{bi}"], x, stride, use_res)
                cin_l = ch(c)
        x = jax.nn.relu6(bn(p["last_bn"], conv(p["last"], x, 1)))
        return dense(p["head"], gap(x))

    return specs, fwd, {"family": "mobilenet", "batch": batch, "res": res}


def build_mnasnet(cfg):
    wm = cfg.get("width", 1.0)
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)
    settings = cfg.get("settings", [
        (3, 24, 3, 2, 3), (3, 40, 3, 2, 5), (6, 80, 3, 2, 5),
        (6, 96, 2, 1, 3), (6, 192, 4, 2, 5), (6, 320, 1, 1, 3)])
    def ch(c): return max(8, int(c * wm))

    specs: Dict[str, Any] = {"stem": _conv_spec(3, ch(32), 3),
                             "stem_bn": _bn_spec(ch(32)),
                             "sep_dw": _dw_spec(ch(32), 3),
                             "sep_bn": _bn_spec(ch(32)),
                             "sep_p": _conv_spec(ch(32), ch(16), 1),
                             "sep_pbn": _bn_spec(ch(16))}
    cin = ch(16)
    for si, (e, c, n, s0, k) in enumerate(settings):
        for bi in range(n):
            specs[f"s{si}b{bi}"] = _inv_residual_specs(cin, ch(c), e, k)
            cin = ch(c)
    specs["head"] = _dense_spec(cin, 1000)

    def fwd(p, x):
        x = jax.nn.relu(bn(p["stem_bn"], conv(p["stem"], x, 2)))
        x = jax.nn.relu(bn(p["sep_bn"], dwconv(p["sep_dw"], x, 1)))
        x = bn(p["sep_pbn"], conv(p["sep_p"], x, 1))
        cin_l = ch(16)
        for si, (e, c, n, s0, k) in enumerate(settings):
            for bi in range(n):
                stride = s0 if bi == 0 else 1
                use_res = stride == 1 and cin_l == ch(c)
                x = _inv_residual(p[f"s{si}b{bi}"], x, stride, use_res)
                cin_l = ch(c)
        return dense(p["head"], gap(x))

    return specs, fwd, {"family": "mnasnet", "batch": batch, "res": res}


def build_efficientnet(cfg):
    wm = cfg.get("width", 1.0)
    dm = cfg.get("depth", 1.0)
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)
    base = [  # (expand, cout, n, stride, k)
        (1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3), (6, 112, 3, 1, 5), (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3)]
    def ch(c): return max(8, int(c * wm))
    def rep(n): return max(1, int(round(n * dm)))

    specs: Dict[str, Any] = {"stem": _conv_spec(3, ch(32), 3),
                             "stem_bn": _bn_spec(ch(32))}
    cin = ch(32)
    for si, (e, c, n, s0, k) in enumerate(base):
        for bi in range(rep(n)):
            blk = _inv_residual_specs(cin, ch(c), e, k)
            mid = cin * e
            sq = max(1, cin // 4)
            blk["se1"] = _dense_spec(mid, sq)
            blk["se2"] = _dense_spec(sq, mid)
            specs[f"s{si}b{bi}"] = blk
            cin = ch(c)
    specs["last"] = _conv_spec(cin, ch(1280), 1)
    specs["last_bn"] = _bn_spec(ch(1280))
    specs["head"] = _dense_spec(ch(1280), 1000)

    def mbconv_se(p, x, stride, use_res):
        y = jax.nn.silu(bn(p["ebn"], conv(p["e"], x, 1)))
        y = jax.nn.silu(bn(p["dwbn"], dwconv(p["dw"], y, stride)))
        s = gap(y)
        s = jax.nn.silu(dense(p["se1"], s))
        s = jax.nn.sigmoid(dense(p["se2"], s))
        y = y * s[:, None, None, :]
        y = bn(p["pbn"], conv(p["p"], y, 1))
        return x + y if use_res else y

    def fwd(p, x):
        x = jax.nn.silu(bn(p["stem_bn"], conv(p["stem"], x, 2)))
        cin_l = ch(32)
        for si, (e, c, n, s0, k) in enumerate(base):
            for bi in range(rep(n)):
                stride = s0 if bi == 0 else 1
                use_res = stride == 1 and cin_l == ch(c)
                x = mbconv_se(p[f"s{si}b{bi}"], x, stride, use_res)
                cin_l = ch(c)
        x = jax.nn.silu(bn(p["last_bn"], conv(p["last"], x, 1)))
        return dense(p["head"], gap(x))

    return specs, fwd, {"family": "efficientnet", "batch": batch, "res": res}


def build_vit(cfg):
    d = cfg.get("dim", 768)
    depth = cfg.get("depth", 12)
    heads = cfg.get("heads", max(1, d // 64))
    patch = cfg.get("patch", 16)
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)
    n_tok = (res // patch) ** 2

    specs: Dict[str, Any] = {
        "embed": _conv_spec(3, d, patch),
        "pos": S((1, n_tok, d), F32),
        "final_ln": _ln_spec(d),
        "head": _dense_spec(d, 1000)}
    for i in range(depth):
        specs[f"blk{i}"] = _tx_spec(d)

    def fwd(p, x):
        x = lax.conv_general_dilated(
            x, p["embed"]["w"], (patch, patch), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        B = x.shape[0]
        x = x.reshape(B, -1, d) + p["pos"]
        for i in range(depth):
            x = tx_block(p[f"blk{i}"], x, heads)
        x = ln(p["final_ln"], x)
        return dense(p["head"], jnp.mean(x, axis=1))

    return specs, fwd, {"family": "vit", "batch": batch, "res": res}


def build_swin(cfg):
    d = cfg.get("dim", 96)
    depths = cfg.get("depths", [2, 2, 6, 2])
    window = cfg.get("window", 7)
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)
    patch = 4

    specs: Dict[str, Any] = {"embed": _conv_spec(3, d, patch)}
    dim = d
    for si, n in enumerate(depths):
        for bi in range(n):
            heads = max(1, dim // 32)
            specs[f"s{si}b{bi}"] = _tx_spec(dim)
        if si < len(depths) - 1:
            specs[f"merge{si}"] = _dense_spec(4 * dim, 2 * dim, bias=False)
            dim *= 2
    specs["final_ln"] = _ln_spec(dim)
    specs["head"] = _dense_spec(dim, 1000)

    def win_attn_block(p, x, hw, dim_l):
        B = x.shape[0]
        H = W = hw
        heads = max(1, dim_l // 32)
        # partition into windows → attention within windows
        xw = x.reshape(B, H // window, window, W // window, window, dim_l)
        xw = xw.transpose(0, 1, 3, 2, 4, 5).reshape(-1, window * window, dim_l)
        xw = tx_block(p, xw, heads)
        xw = xw.reshape(B, H // window, W // window, window, window, dim_l)
        x = xw.transpose(0, 1, 3, 2, 4, 5).reshape(B, H * W, dim_l)
        return x

    def fwd(p, x):
        x = lax.conv_general_dilated(
            x, p["embed"]["w"], (patch, patch), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        B, H, W, _ = x.shape
        dim_l = d
        hw = H
        x = x.reshape(B, H * W, d)
        for si, n in enumerate(depths):
            for bi in range(n):
                x = win_attn_block(p[f"s{si}b{bi}"], x, hw, dim_l)
            if si < len(depths) - 1:
                # patch merging: 2x2 neighborhood concat + linear
                x = x.reshape(B, hw // 2, 2, hw // 2, 2, dim_l)
                x = x.transpose(0, 1, 3, 2, 4, 5).reshape(
                    B, (hw // 2) ** 2, 4 * dim_l)
                x = dense(p[f"merge{si}"], x)
                dim_l *= 2
                hw //= 2
        x = ln(p["final_ln"], x)
        return dense(p["head"], jnp.mean(x, axis=1))

    return specs, fwd, {"family": "swin", "batch": batch, "res": res}


def build_visformer(cfg):
    d = cfg.get("dim", 384)
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)
    conv_depth = cfg.get("conv_depth", 4)
    tx_depth = cfg.get("tx_depth", 4)
    heads = max(1, d // 64)

    specs: Dict[str, Any] = {"stem": _conv_spec(3, d // 4, 7),
                             "stem_bn": _bn_spec(d // 4)}
    c = d // 4
    for i in range(conv_depth):
        specs[f"conv{i}"] = {"c1": _conv_spec(c, c, 3), "bn1": _bn_spec(c),
                             "c2": _conv_spec(c, c, 3), "bn2": _bn_spec(c)}
    specs["proj"] = _conv_spec(c, d, 2)
    for i in range(tx_depth):
        specs[f"blk{i}"] = _tx_spec(d)
    specs["final_ln"] = _ln_spec(d)
    specs["head"] = _dense_spec(d, 1000)

    def fwd(p, x):
        x = jax.nn.relu(bn(p["stem_bn"], conv(p["stem"], x, 2)))
        x = maxpool(x)
        for i in range(conv_depth):
            blk = p[f"conv{i}"]
            y = jax.nn.relu(bn(blk["bn1"], conv(blk["c1"], x)))
            y = bn(blk["bn2"], conv(blk["c2"], y))
            x = jax.nn.relu(x + y)
        x = lax.conv_general_dilated(
            x, p["proj"]["w"], (2, 2), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        B, H, W, _ = x.shape
        x = x.reshape(B, H * W, d)
        for i in range(tx_depth):
            x = tx_block(p[f"blk{i}"], x, heads)
        x = ln(p["final_ln"], x)
        return dense(p["head"], jnp.mean(x, axis=1))

    return specs, fwd, {"family": "visformer", "batch": batch, "res": res}


def build_poolformer(cfg):
    d = cfg.get("dim", 64)
    depths = cfg.get("depths", [2, 2, 6, 2])
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)

    dims = [d, d * 2, d * 4, d * 8]
    specs: Dict[str, Any] = {"embed": _conv_spec(3, dims[0], 7)}
    for si, n in enumerate(depths):
        dim = dims[si]
        for bi in range(n):
            specs[f"s{si}b{bi}"] = {
                "ln1": _bn_spec(dim), "ln2": _bn_spec(dim),
                "fc1": _conv_spec(dim, dim * 4, 1),
                "fc2": _conv_spec(dim * 4, dim, 1)}
        if si < len(depths) - 1:
            specs[f"down{si}"] = _conv_spec(dim, dims[si + 1], 3)
    specs["head"] = _dense_spec(dims[-1], 1000)

    def fwd(p, x):
        x = lax.conv_general_dilated(
            x, p["embed"]["w"], (4, 4), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        for si, n in enumerate(depths):
            for bi in range(n):
                blk = p[f"s{si}b{bi}"]
                # token mixer: pooling - identity
                y = bn(blk["ln1"], x)
                y = avgpool(y, 3, 1) - y
                x = x + y
                y = bn(blk["ln2"], x)
                y = jax.nn.gelu(conv(blk["fc1"], y, 1))
                x = x + conv(blk["fc2"], y, 1)
            if si < len(depths) - 1:
                x = conv(p[f"down{si}"], x, 2)
        return dense(p["head"], gap(x))

    return specs, fwd, {"family": "poolformer", "batch": batch, "res": res}


def build_convnext(cfg):
    """Held-out family — used only for the Table-5 'unseen' evaluation."""
    d = cfg.get("dim", 128)
    depths = cfg.get("depths", [3, 3, 9, 3])
    res, batch = cfg.get("res", 224), cfg.get("batch", 1)
    dims = [d, d * 2, d * 4, d * 8]

    specs: Dict[str, Any] = {"stem": _conv_spec(3, dims[0], 4)}
    for si, n in enumerate(depths):
        dim = dims[si]
        for bi in range(n):
            specs[f"s{si}b{bi}"] = {
                "dw": _dw_spec(dim, 7), "ln": _ln_spec(dim),
                "fc1": _dense_spec(dim, 4 * dim),
                "fc2": _dense_spec(4 * dim, dim)}
        if si < len(depths) - 1:
            specs[f"down{si}"] = _conv_spec(dim, dims[si + 1], 2)
    specs["final_ln"] = _ln_spec(dims[-1])
    specs["head"] = _dense_spec(dims[-1], 1000)

    def fwd(p, x):
        x = lax.conv_general_dilated(
            x, p["stem"]["w"], (4, 4), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        for si, n in enumerate(depths):
            for bi in range(n):
                blk = p[f"s{si}b{bi}"]
                y = dwconv(blk["dw"], x, 1)
                y = ln(blk["ln"], y)
                y = jax.nn.gelu(dense(blk["fc1"], y))
                y = dense(blk["fc2"], y)
                x = x + y
            if si < len(depths) - 1:
                x = lax.conv_general_dilated(
                    x, p[f"down{si}"]["w"], (2, 2), "VALID",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = ln(p["final_ln"], gap(x)[:, None, :])[:, 0]
        return dense(p["head"], x)

    return specs, fwd, {"family": "convnext", "batch": batch, "res": res}


FAMILIES: Dict[str, Callable] = {
    "efficientnet": build_efficientnet,
    "mnasnet": build_mnasnet,
    "mobilenet": build_mobilenet,
    "resnet": build_resnet,
    "vgg": build_vgg,
    "swin": build_swin,
    "vit": build_vit,
    "densenet": build_densenet,
    "visformer": build_visformer,
    "poolformer": build_poolformer,
    "convnext": build_convnext,   # held out of training (Table 5 'unseen')
}

#: Table 2 distribution (family → fraction of the 10,508 graphs)
TABLE2_FRACTIONS: Dict[str, float] = {
    "efficientnet": 0.1645, "mnasnet": 0.0953, "mobilenet": 0.1514,
    "resnet": 0.1096, "vgg": 0.1462, "swin": 0.0521, "vit": 0.0495,
    "densenet": 0.0731, "visformer": 0.0731, "poolformer": 0.0853,
}


def family_variants(family: str, rng) -> Dict[str, Any]:
    """Sample one variant config for a family (seeded RNG)."""
    batch = int(rng.choice([1, 2, 4, 8, 16, 32, 64]))
    res = int(rng.choice([128, 160, 192, 224, 256]))
    cfg: Dict[str, Any] = {"batch": batch, "res": res}
    if family == "vgg":
        cfg["convs"] = list(rng.choice(
            [[1, 1, 2, 2, 2], [2, 2, 2, 2, 2], [2, 2, 3, 3, 3],
             [2, 2, 4, 4, 4]]))
        cfg["width"] = float(rng.choice([0.5, 0.75, 1.0]))
    elif family == "resnet":
        cfg["depths"] = list(rng.choice(
            [[2, 2, 2, 2], [3, 4, 6, 3], [2, 3, 4, 2]]))
        cfg["bottleneck"] = bool(rng.random() < 0.5)
        cfg["width"] = float(rng.choice([0.5, 0.75, 1.0]))
    elif family == "densenet":
        cfg["blocks"] = list(rng.choice(
            [[6, 12, 24, 16], [6, 12, 32, 32], [4, 8, 16, 12], [3, 6, 12, 8]]))
        cfg["growth"] = int(rng.choice([16, 24, 32]))
    elif family in ("mobilenet", "mnasnet"):
        cfg["width"] = float(rng.choice([0.35, 0.5, 0.75, 1.0, 1.4]))
    elif family == "efficientnet":
        cfg["width"] = float(rng.choice([0.75, 1.0, 1.1, 1.2]))
        cfg["depth"] = float(rng.choice([0.8, 1.0, 1.1, 1.2]))
    elif family == "vit":
        cfg["dim"] = int(rng.choice([192, 384, 768]))
        cfg["depth"] = int(rng.choice([6, 8, 12]))
        cfg["patch"] = int(rng.choice([16, 32]))
        cfg["res"] = 224
    elif family == "swin":
        cfg["dim"] = int(rng.choice([64, 96, 128]))
        cfg["depths"] = list(rng.choice([[2, 2, 6, 2], [2, 2, 2, 2]]))
        cfg["res"] = 224
    elif family == "visformer":
        cfg["dim"] = int(rng.choice([192, 384]))
        cfg["conv_depth"] = int(rng.choice([2, 4, 6]))
        cfg["tx_depth"] = int(rng.choice([2, 4, 6]))
    elif family == "poolformer":
        cfg["dim"] = int(rng.choice([32, 48, 64, 96]))
        cfg["depths"] = list(rng.choice([[2, 2, 6, 2], [4, 4, 12, 4]]))
    elif family == "convnext":
        cfg["dim"] = int(rng.choice([96, 128]))
        cfg["depths"] = list(rng.choice([[3, 3, 9, 3], [2, 2, 6, 2]]))
    return cfg


def build_family(family: str, cfg: Dict[str, Any]):
    """→ (param_specs, forward, meta). ``meta`` includes batch/res/family."""
    specs, fwd, meta = FAMILIES[family](cfg)
    meta.update({k: v for k, v in cfg.items() if k not in meta})
    return specs, fwd, meta


def trace_family(family: str, cfg: Dict[str, Any]):
    """Build one family variant and trace it into an ``OpGraph``.

    The standard image input spec ``[batch, res, res, 3]`` is derived from
    ``cfg`` (defaults: batch 1, res 224). This is the zoo→predictor glue
    used by the dataset builder and ``DIPPM.predict_zoo``.
    """
    from ..core.frontends import from_jax
    specs, fwd, meta = build_family(family, cfg)
    batch = int(cfg.get("batch", 1))
    res = int(cfg.get("res", 224))
    return from_jax(fwd, specs, S((batch, res, res, 3), F32), meta=meta)


def variant_grid(family: str,
                 axes: Dict[str, Sequence[Any]]) -> List[Dict[str, Any]]:
    """Cartesian product of config axes → list of variant configs.

        variant_grid("vit", {"depth": [6, 12], "dim": [192, 384],
                             "batch": [1, 8]})

    yields 8 configs ready for :func:`build_family` / ``predict_zoo``.
    ``family`` is only validated (KeyError on unknown family); axes are
    passed through untouched.
    """
    if family not in FAMILIES:
        raise KeyError(f"unknown zoo family: {family!r}")
    keys = list(axes)
    out: List[Dict[str, Any]] = []
    for combo in itertools.product(*(axes[k] for k in keys)):
        out.append(dict(zip(keys, combo)))
    return out
