"""End-to-end driver: factory-build the DIPPM dataset, train the PMGNS
predictor, evaluate MAPE per target, save the predictor.

    PYTHONPATH=src python examples/train_dippm.py --n-graphs 400 --epochs 20

The dataset is built by the sharded ``repro.dataset.factory`` under
``artifacts/datasets`` keyed by plan hash: interrupted builds resume
from committed shards, repeat runs verify checksums and skip tracing,
and ``--workers N`` parallelises tracing across processes. Long
training runs survive interruption too: pass ``--checkpoint-dir
artifacts/ckpt`` and re-run the same command after a kill (see
docs/training.md and docs/dataset.md).
"""
import argparse
import os

from repro.core import PMGNSConfig, DIPPM
from repro.dataset.builder import records_to_samples, split_dataset
from repro.dataset.factory import (FactoryConfig, build, iter_records,
                                   plan_hash)
from repro.train.gnn_trainer import TrainConfig, evaluate, train_pmgns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-graphs", type=int, default=300)
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--hidden", type=int, default=512)
    ap.add_argument("--lr", type=float, default=2.754e-5 * 400)
    ap.add_argument("--variant", default="graphsage")
    ap.add_argument("--out", default="artifacts/dippm.npz")
    ap.add_argument("--dataset-dir", default=None,
                    help="factory dataset directory "
                         "(default: artifacts/datasets/train-<planhash>)")
    ap.add_argument("--workers", type=int, default=1,
                    help="worker processes for the dataset build")
    ap.add_argument("--lm-archs", nargs="*", default=(),
                    help="LLM configs to trace into the dataset, e.g. "
                         "qwen2.5-3b mamba2-370m")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="checkpoint every epoch here and resume from it")
    ap.add_argument("--data-parallel", action="store_true",
                    help="shard the batch axis over all local devices")
    args = ap.parse_args()

    ds_cfg = FactoryConfig(n_graphs=args.n_graphs, seed=0,
                           extra_families=("convnext",),
                           lm_archs=tuple(args.lm_archs))
    out_dir = args.dataset_dir or os.path.join(
        "artifacts", "datasets", f"train-{plan_hash(ds_cfg)[:16]}")
    res = build(out_dir, ds_cfg, workers=args.workers, progress=True)
    print(f"dataset: {res.n_built}/{res.n_planned} graphs, "
          f"{res.n_shards} shards ({res.shards_reused} reused), "
          f"{res.n_skipped} skipped → {out_dir}")
    recs = list(iter_records(out_dir))
    sp = split_dataset(recs, seed=0)
    print({k: len(v) for k, v in sp.items()})

    cfg = PMGNSConfig(variant=args.variant, hidden=args.hidden)
    params, hist = train_pmgns(
        cfg, records_to_samples(sp["train"]),
        records_to_samples(sp["val"]),
        TrainConfig(epochs=args.epochs, batch_size=32, lr=args.lr,
                    log_every=1, data_parallel=args.data_parallel,
                    checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=1 if args.checkpoint_dir else 0),
        resume_from=args.checkpoint_dir)

    for split in ("val", "test", "unseen"):
        if sp[split]:
            m = evaluate(params, cfg, records_to_samples(sp[split]))
            print(f"{split:7s} MAPE={m['mape']:.4f} "
                  f"(latency={m['mape_latency']:.4f} "
                  f"energy={m['mape_energy']:.4f} "
                  f"memory={m['mape_memory']:.4f})")

    DIPPM.from_params(params, cfg).save(args.out)
    print(f"saved predictor → {args.out}")


if __name__ == "__main__":
    main()
