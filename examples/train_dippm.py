"""End-to-end driver: build the DIPPM dataset, train the PMGNS predictor
for a few hundred steps, evaluate MAPE per target, save the predictor.

    PYTHONPATH=src python examples/train_dippm.py --n-graphs 400 --epochs 20

Long runs survive interruption: pass ``--checkpoint-dir artifacts/ckpt``
and re-run the same command after a kill — training resumes from the
latest committed checkpoint and finishes as if uninterrupted (see
docs/training.md).
"""
import argparse

from repro.core import PMGNSConfig, DIPPM
from repro.dataset.builder import (build_dataset, records_to_samples,
                                   save_dataset, split_dataset)
from repro.train.gnn_trainer import TrainConfig, evaluate, train_pmgns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-graphs", type=int, default=300)
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--hidden", type=int, default=512)
    ap.add_argument("--lr", type=float, default=2.754e-5 * 400)
    ap.add_argument("--variant", default="graphsage")
    ap.add_argument("--out", default="artifacts/dippm.npz")
    ap.add_argument("--save-dataset", default=None)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="checkpoint every epoch here and resume from it")
    ap.add_argument("--data-parallel", action="store_true",
                    help="shard the batch axis over all local devices")
    args = ap.parse_args()

    recs = build_dataset(n_graphs=args.n_graphs, seed=0,
                         extra_families=("convnext",), progress_every=100)
    if args.save_dataset:
        save_dataset(recs, args.save_dataset)
    sp = split_dataset(recs, seed=0)
    print({k: len(v) for k, v in sp.items()})

    cfg = PMGNSConfig(variant=args.variant, hidden=args.hidden)
    params, hist = train_pmgns(
        cfg, records_to_samples(sp["train"]),
        records_to_samples(sp["val"]),
        TrainConfig(epochs=args.epochs, batch_size=32, lr=args.lr,
                    log_every=1, data_parallel=args.data_parallel,
                    checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=1 if args.checkpoint_dir else 0),
        resume_from=args.checkpoint_dir)

    for split in ("val", "test", "unseen"):
        if sp[split]:
            m = evaluate(params, cfg, records_to_samples(sp[split]))
            print(f"{split:7s} MAPE={m['mape']:.4f} "
                  f"(latency={m['mape_latency']:.4f} "
                  f"energy={m['mape_energy']:.4f} "
                  f"memory={m['mape_memory']:.4f})")

    DIPPM.from_params(params, cfg).save(args.out)
    print(f"saved predictor → {args.out}")


if __name__ == "__main__":
    main()
