"""Serve a small LM with batched requests: prefill → batched greedy
decode with a KV cache, plus the DIPPM-style resource recommendation for
the serving footprint.

    PYTHONPATH=src python examples/serve_lm.py --requests 4 --new-tokens 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.mig import predict_tpu_slice
from repro.models import lm
from repro import nn as rnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    B, S = args.requests, args.prompt_len
    max_len = S + args.new_tokens

    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab)
    inputs = {"tokens": prompts}
    if cfg.frontend == "tokens+vision":
        inputs["vision_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.vision_dim))

    # resource advice from the serving footprint (params + cache)
    cache = lm.init_cache(cfg, B, max_len)
    footprint_mb = (rnn.tree_bytes(params) + rnn.tree_bytes(cache)) / 1e6
    print(f"serving footprint ≈ {footprint_mb:.1f} MB → "
          f"slice {predict_tpu_slice(footprint_mb * 1.3)}")

    t0 = time.time()
    logits, cache = lm.prefill(params, cfg, inputs, max_len)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    print(f"prefill {B}×{S} in {time.time() - t0:.2f}s")

    decode = jax.jit(
        lambda p, c, t, i: lm.decode_step(p, cfg, c, {"tokens": t}, i))
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decoded {args.new_tokens} tokens × {B} requests "
          f"in {dt:.2f}s ({B * args.new_tokens / dt:.1f} tok/s)")
    for r in range(min(B, 2)):
        print(f"req{r}: {gen[r][:16].tolist()} ...")


if __name__ == "__main__":
    main()
