"""Serving demo — concurrent requests through `repro.serve`.

Spins up a :class:`~repro.serve.PredictionService` over a packed-layout
predictor, precompiles the budget-rung ladder, then fires N concurrent
zoo-variant requests from worker threads (each thread traces its own
variant and submits — exactly the shape of design-space-exploration
traffic hitting a shared predictor). Prints per-request latency and the
final :class:`~repro.serve.ServeStats`: watch ``batch_occupancy`` — the
micro-batcher coalesces the burst into a handful of packed bins instead
of one device dispatch per request. A second pass re-submits the same
variants to show the content-addressed prediction cache: every
duplicate resolves from the fingerprint LRU (``cache_hits``) without
touching the engine, bit-equal to the first pass.

    PYTHONPATH=src python examples/serve_requests.py
"""
import threading

import jax

from repro.core import DIPPM, PMGNSConfig, pmgns_init
from repro.zoo.families import trace_family, variant_grid

N_THREADS = 8
REQUESTS_PER_THREAD = 4


def main():
    # a trained predictor would come from DIPPM.load("model.npz");
    # random params keep the demo self-contained and fast
    cfg = PMGNSConfig(hidden=64, layout="packed")
    dippm = DIPPM.from_params(pmgns_init(jax.random.PRNGKey(0), cfg), cfg)

    grid = variant_grid("mobilenet", {
        "width": [0.35, 0.5, 0.75, 1.0],
        "res": [96, 128, 160, 192],
        "batch": [1, 8],
    })[:N_THREADS * REQUESTS_PER_THREAD]
    print(f"== tracing {len(grid)} mobilenet variants ==")
    graphs = [trace_family("mobilenet", v) for v in grid]

    with dippm.serve(max_wait_ms=5.0, max_batch_graphs=64) as svc:
        print(f"== warmup: {svc.warmup()} budget-rung shapes compiled ==")

        results = [None] * len(graphs)

        def worker(tid: int):
            for k in range(tid, len(graphs), N_THREADS):
                fut = svc.submit(graphs[k])      # returns immediately
                results[k] = (grid[k], fut.result(timeout=120), fut)

        print(f"== firing {len(graphs)} concurrent requests from "
              f"{N_THREADS} threads ==")
        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        print(f"\n{'variant':<38}{'latency':>10}{'memory':>11}"
              f"{'served in':>11}")
        for v, pred, fut in results:
            name = (f"w{v['width']} r{v['res']} b{v['batch']}")
            print(f"{name:<38}{pred.latency_ms:>8.2f}ms"
                  f"{pred.memory_mb:>9.1f}MB{fut.latency_ms:>9.1f}ms")

        # duplicate traffic: the design-space explorer re-queries the
        # same variants — all of them resolve from the prediction cache
        print(f"\n== re-submitting all {len(graphs)} variants "
              f"(duplicates) ==")
        dup = [svc.submit(g) for g in graphs]
        svc.flush()
        for (_, first, _), fut in zip(results, dup):
            again = fut.result(timeout=120)
            assert again.latency_ms == first.latency_ms  # bit-equal hit

        s = svc.stats
        print(f"\n== ServeStats ==")
        print(f"requests : {s.completed} completed / {s.submitted} "
              f"submitted (peak queue depth {s.queue_peak}, "
              f"shed {s.shed_count})")
        print(f"batching : {s.batches} drains, {s.bins} device bins, "
              f"occupancy {s.batch_occupancy:.1f} graphs/drain")
        print(f"cache    : {s.cache_hits} hits + {s.cache_coalesced} "
              f"coalesced / {s.cache_misses} misses "
              f"(hit rate {s.hit_rate:.1%}, {s.cache_entries} entries)")
        print(f"fleet    : {s.replicas} replica(s)"
              + (f", bins per replica {list(s.replica_bins)}, "
                 f"requeues {s.requeues}" if s.replicas > 1 else ""))
        print(f"padding  : {s.padding_waste_frac:.1%} of device node rows")
        print(f"latency  : p50 {s.latency_ms_p50:.1f} ms, "
              f"p99 {s.latency_ms_p99:.1f} ms")


if __name__ == "__main__":
    main()
