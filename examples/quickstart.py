"""Quickstart — the paper's Fig. 5 usability surface.

Train a small DIPPM on a freshly-generated dataset slice, then predict
latency / energy / memory / MIG profile / TPU slice for (a) a zoo CNN and
(b) an assigned LM architecture — without running either model.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
from jax import ShapeDtypeStruct as S

from repro.core import DIPPM, PMGNSConfig
from repro.core.frontends import from_jax
from repro.dataset.builder import (build_dataset, records_to_samples,
                                   split_dataset)
from repro.train.gnn_trainer import TrainConfig, train_pmgns


def main():
    print("== building dataset (Table-2 families, analytic A100 labels) ==")
    recs = build_dataset(n_graphs=120, seed=0)
    sp = split_dataset(recs, seed=0)
    cfg = PMGNSConfig(hidden=128)
    params, hist = train_pmgns(
        cfg, records_to_samples(sp["train"]),
        records_to_samples(sp["val"]),
        TrainConfig(epochs=8, batch_size=16, lr=5e-3, log_every=2))
    dippm = DIPPM.from_params(params, cfg)

    # --- predict a zoo model (paper Fig. 5: vgg16-style) -----------------
    from repro.zoo.families import build_family
    specs, fwd, meta = build_family("vgg", {"batch": 8, "res": 224,
                                            "convs": [2, 2, 3, 3, 3]})
    pred = dippm.predict_jax(fwd, specs,
                             S((8, 224, 224, 3), jnp.float32),
                             batch=8, meta=meta)
    print(f"\nvgg16 @ batch 8      → {pred}")

    # --- predict an assigned architecture (reduced config) ----------------
    from repro.configs import get_smoke_config
    from repro.models import lm
    acfg = get_smoke_config("qwen2.5-3b")
    pspecs = lm.param_specs(acfg)

    def forward(params_, tokens):
        logits, _ = lm.forward(params_, acfg, {"tokens": tokens})
        return logits

    pred2 = dippm.predict_jax(forward, pspecs, S((4, 128), jnp.int32),
                              batch=4, meta={"family": "qwen"})
    print(f"qwen-smoke @ batch 4 → {pred2}")


if __name__ == "__main__":
    main()
