"""Train a ~100M-param LM for a few hundred steps on the synthetic corpus
— the substrate end-to-end: data pipeline → sharded train step →
checkpointing → fault-tolerant supervisor (with an injected failure).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.data import SyntheticLMDataset
from repro.models import lm
from repro.models.config import ArchConfig
from repro.launch import steps as steps_mod
from repro.optim import adamw, cosine_warmup
from repro.runtime.fault import FailureInjector, TrainingSupervisor

#: ~100M params: 12L × d512 × ff2048, vocab 8192
CFG_100M = ArchConfig(
    name="lm-100m", n_layers=12, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab=8192, param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="artifacts/lm100m_ckpt")
    ap.add_argument("--inject-failure", action="store_true")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"params: {cfg.param_count() / 1e6:.1f}M")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    opt = adamw(cosine_warmup(3e-4, 20, args.steps), b1=0.9, b2=0.95,
                weight_decay=0.1, grad_clip_norm=1.0)
    opt_state = opt.init(params)
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq, seed=0)

    ctx = lm.ParallelCtx(remat=False)

    @jax.jit
    def train_step(params, opt_state, step, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch, ctx), has_aux=True)(params)
        params, opt_state = opt.update(step, opt_state, params, grads)
        return params, opt_state, loss

    state = {"params": params, "opt": opt_state}
    injector = FailureInjector([args.steps // 2] if args.inject_failure
                               else [])
    sup = TrainingSupervisor(args.ckpt, save_every=50, injector=injector)

    losses = []
    t0 = time.time()

    def step_fn(state, step):
        batch = {k: jnp.asarray(v)
                 for k, v in ds.batch(step, args.batch).items()}
        p, o, loss = train_step(state["params"], state["opt"],
                                jnp.asarray(step), batch)
        if step % 20 == 0:
            losses.append(float(loss))
            tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({tok_s:,.0f} tok/s)", flush=True)
        return {"params": p, "opt": o}

    report = sup.run(state, step_fn, total_steps=args.steps)
    print(f"done: {report.steps_run} steps, {report.restarts} restarts")
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} → {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
