"""Rapid design-space exploration with DIPPM (paper §1: "helps to perform
rapid design-space exploration for the inference performance of a model").

Sweeps a ViT family over (depth × width × batch), predicts latency /
memory for every point WITHOUT running any of them, and prints the
Pareto-optimal configurations under a memory budget.

    PYTHONPATH=src python examples/design_space_exploration.py
"""
import itertools

import jax.numpy as jnp
from jax import ShapeDtypeStruct as S

from repro.core import DIPPM, PMGNSConfig
from repro.core.frontends import from_jax
from repro.dataset.builder import (build_dataset, records_to_samples,
                                   split_dataset)
from repro.train.gnn_trainer import TrainConfig, train_pmgns
from repro.zoo.families import build_family


def main():
    recs = build_dataset(n_graphs=150, seed=1)
    sp = split_dataset(recs, seed=1)
    cfg = PMGNSConfig(hidden=128)
    params, _ = train_pmgns(
        cfg, records_to_samples(sp["train"]),
        records_to_samples(sp["val"]),
        TrainConfig(epochs=8, batch_size=16, lr=5e-3))
    dippm = DIPPM.from_params(params, cfg)

    budget_mb = 5 * 1024.0       # must fit a 1g.5gb MIG instance
    points = []
    for depth, dim, batch in itertools.product(
            [6, 8, 12], [192, 384, 768], [1, 8, 32]):
        specs, fwd, meta = build_family(
            "vit", {"depth": depth, "dim": dim, "batch": batch,
                    "res": 224})
        pred = dippm.predict_jax(
            fwd, specs, S((batch, 224, 224, 3), jnp.float32),
            batch=batch, meta=meta)
        points.append(((depth, dim, batch), pred))

    feasible = [(k, p) for k, p in points if p.memory_mb < budget_mb]
    # pareto: lowest latency per (depth·dim) capacity proxy
    feasible.sort(key=lambda kp: kp[1].latency_ms)
    print(f"{len(feasible)}/{len(points)} configs fit under "
          f"{budget_mb:.0f} MB (1g.5gb)\n")
    print("depth dim  batch   latency_ms  memory_mb  mig       tpu_slice")
    pareto_cap = 0
    for (d, w, b), p in feasible:
        cap = d * w
        if cap > pareto_cap:     # larger model at this latency rank
            pareto_cap = cap
            print(f"{d:4d} {w:5d} {b:4d}   {p.latency_ms:9.3f} "
                  f"{p.memory_mb:9.1f}  {str(p.mig):8s} {p.tpu_slice}")


if __name__ == "__main__":
    main()
