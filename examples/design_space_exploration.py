"""Rapid design-space exploration with DIPPM (paper §1: "helps to perform
rapid design-space exploration for the inference performance of a model").

Sweeps a ViT family over (depth × width × batch) with the **batched
prediction engine** (``DIPPM.predict_zoo``): all 27 candidates are traced,
bucketed by padded size, and scored in a handful of jit-compiled batched
apply calls — no candidate is ever executed. Prints the Pareto-optimal
configurations under a memory budget plus engine throughput stats.

    PYTHONPATH=src python examples/design_space_exploration.py
"""
from repro.core import DIPPM, PMGNSConfig
from repro.dataset.builder import (build_dataset, records_to_samples,
                                   split_dataset)
from repro.train.gnn_trainer import TrainConfig, train_pmgns
from repro.zoo.families import variant_grid


def main():
    recs = build_dataset(n_graphs=150, seed=1)
    sp = split_dataset(recs, seed=1)
    cfg = PMGNSConfig(hidden=128)
    params, _ = train_pmgns(
        cfg, records_to_samples(sp["train"]),
        records_to_samples(sp["val"]),
        TrainConfig(epochs=8, batch_size=16, lr=5e-3))
    dippm = DIPPM.from_params(params, cfg)

    budget_mb = 5 * 1024.0       # must fit a 1g.5gb MIG instance
    grid = variant_grid("vit", {"depth": [6, 8, 12],
                                "dim": [192, 384, 768],
                                "batch": [1, 8, 32],
                                "res": [224]})
    points = [((c["depth"], c["dim"], c["batch"]), p)
              for c, p in dippm.predict_zoo("vit", grid)]
    st = dippm.engine().stats
    print(f"engine: {st.graphs_predicted} graphs in {st.batches_run} "
          f"batched calls ({st.cache_misses} compiles)\n")

    feasible = [(k, p) for k, p in points if p.memory_mb < budget_mb]
    # pareto: lowest latency per (depth·dim) capacity proxy
    feasible.sort(key=lambda kp: kp[1].latency_ms)
    print(f"{len(feasible)}/{len(points)} configs fit under "
          f"{budget_mb:.0f} MB (1g.5gb)\n")
    print("depth dim  batch   latency_ms  memory_mb  mig       tpu_slice")
    pareto_cap = 0
    for (d, w, b), p in feasible:
        cap = d * w
        if cap > pareto_cap:     # larger model at this latency rank
            pareto_cap = cap
            print(f"{d:4d} {w:5d} {b:4d}   {p.latency_ms:9.3f} "
                  f"{p.memory_mb:9.1f}  {str(p.mig):8s} {p.tpu_slice}")


if __name__ == "__main__":
    main()
